"""Model-stack correctness: chunked attention vs reference, SSD layer vs
kernel oracle, prefill/decode consistency, MoE invariants, config smoke
(one reduced train/forward step per assigned architecture)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional [dev] dependency
    from repro.testing import given, settings, st

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (decode_step, encode, init_cache, init_params,
                          model_schema, prefill, train_loss)
from repro.models.attention import (chunked_attention,
                                    reference_attention)
from repro.models.config import SHAPES
from repro.models.moe import moe_ffn
from repro.models.transformer import layer_plan


def _rand(seed, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32
                             ).astype(dtype)


# ------------------------------------------------------------------ #
# chunked attention == reference
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 48)])
def test_chunked_attention_matches_reference(causal, window):
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 32
    q, k, v = (_rand(i, (B, S, Hq if i == 1 else Hkv, D))
               for i in (1, 2, 3))
    o = chunked_attention(q, k, v, causal=causal, window=window,
                          chunk_q=32, chunk_kv=32)
    r = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(cq=st.sampled_from([16, 32, 64, 128]),
       ckv=st.sampled_from([16, 32, 64, 128]))
def test_chunked_attention_chunk_invariance(cq, ckv):
    B, S, H, D = 1, 128, 2, 32
    q, k, v = (_rand(i + 10, (B, S, H, D)) for i in range(3))
    o1 = chunked_attention(q, k, v, chunk_q=cq, chunk_kv=ckv)
    o2 = reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_causal_skip_matches_masked_path():
    B, S, H, D = 1, 128, 2, 32
    q, k, v = (_rand(i + 20, (B, S, H, D)) for i in range(3))
    o1 = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, chunk_q=32, chunk_kv=32, causal_skip=True))(q, k, v)
    o2 = chunked_attention(q, k, v, chunk_q=32, chunk_kv=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


# ------------------------------------------------------------------ #
# prefill + decode == full forward (the serving-correctness invariant)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-370m",
                                  "jamba-1.5-large-398b",
                                  "h2o-danube-3-4b"])
def test_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    tol = 3e-2
    if cfg.is_moe:
        # capacity drops are train-time semantics; decode never drops —
        # equality only holds with ample capacity.  Near-tie router
        # logits can still flip expert choice between the two paths
        # (bf16 summation-order differences), swapping whole expert
        # outputs for a few tokens — intrinsic MoE behaviour, so the
        # elementwise tolerance is wider for MoE archs.
        cfg = cfg.with_updates(capacity_factor=8.0)
        tol = 1.5e-1
    params = init_params(model_schema(cfg), jax.random.key(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 1,
                                cfg.vocab_size)
    # full forward logits at position S-1 predict token S
    from repro.models.transformer import forward
    full_x, _, _ = forward(params, {"tokens": tokens}, cfg)
    full_logits = (full_x[:, S - 1:S + 1] @ params["lm_head"]
                   ).astype(jnp.float32)

    # prefill S tokens, then decode one step
    logits_p, caches = prefill(params, {"tokens": tokens[:, :S]}, cfg)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full_logits[:, 0]),
                               atol=tol, rtol=tol)

    cache = init_cache(cfg, B, S + 8)
    # replay tokens 0..S-1 through decode to build the same cache state
    logits_d = None
    for t in range(S + 1):
        logits_d, cache = decode_step(params, tokens[:, t:t + 1],
                                      jnp.int32(t), cache, cfg)
        if t == S - 1:
            np.testing.assert_allclose(
                np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, 0]),
                atol=tol, rtol=1.0)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, 1]),
        atol=tol, rtol=1.0)


# ------------------------------------------------------------------ #
# MoE invariants
# ------------------------------------------------------------------ #
def test_moe_capacity_and_combination():
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    params = init_params(model_schema(cfg), jax.random.key(0))
    _, period, _ = layer_plan(cfg)
    moe_params = jax.tree.map(lambda x: x[0],
                              params["stack"][0]["ffn"])
    x = _rand(30, (64, cfg.d_model), jnp.bfloat16)
    out, aux = moe_ffn(moe_params, x, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0.9  # balanced-ish routing has aux ~ 1
    assert not bool(jnp.isnan(out).any())


def test_moe_permutation_equivariance():
    """Property: permuting tokens permutes outputs (routing is
    tokenwise; capacity dropping is order-dependent only on overflow,
    so use a tiny token count with generous capacity)."""
    cfg = get_smoke_config("kimi-k2-1t-a32b").with_updates(
        capacity_factor=8.0)
    params = init_params(model_schema(cfg), jax.random.key(0))
    moe_params = jax.tree.map(lambda x: x[0], params["stack"][0]["ffn"])
    x = _rand(31, (32, cfg.d_model), jnp.bfloat16)
    perm = np.random.RandomState(0).permutation(32)
    out1, _ = moe_ffn(moe_params, x, cfg)
    out2, _ = moe_ffn(moe_params, x[perm], cfg)
    np.testing.assert_allclose(np.asarray(out1[perm], np.float32),
                               np.asarray(out2, np.float32),
                               atol=3e-2, rtol=3e-2)


# ------------------------------------------------------------------ #
# per-arch smoke: one reduced train (or encode) step, shapes + no NaNs
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(model_schema(cfg), jax.random.key(0))
    B, S = 2, 128
    if cfg.modality == "audio":
        batch = {"frames": jnp.ones((B, S, 512), jnp.bfloat16),
                 "labels": jnp.zeros((B, S), jnp.int32)}
        logits = encode(params, batch, cfg)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        return
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.modality == "vision":
        batch["patches"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                    jnp.bfloat16)
    loss, grads = jax.value_and_grad(train_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned dimensions."""
    spec = {
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 65536),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163840),
        "grok-1-314b": (64, 6144, 48, 8, 131072),
        "qwen1.5-32b": (64, 5120, 40, 40, 152064),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 32000),
        "nemotron-4-340b": (96, 18432, 96, 8, 256000),
        "qwen2.5-3b": (36, 2048, 16, 2, 151936),
        "hubert-xlarge": (48, 1280, 16, 16, 504),
        "mamba2-370m": (48, 1024, 0, 0, 50280),
        "llava-next-34b": (60, 7168, 56, 8, 64000),
    }[arch]
    cfg = get_config(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab_size) == spec
