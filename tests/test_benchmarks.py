"""Benchmark-harness correctness: every paper-table cell matches, and the
roofline report parses the dry-run artifacts."""
import os

import pytest

from benchmarks import paper_tables


@pytest.mark.parametrize("table", ["table1", "table2", "table4",
                                   "table5", "table6", "table7",
                                   "fma_example", "ecm", "registry"])
def test_paper_table_matches(table):
    rows = paper_tables.ALL_TABLES[table]()
    assert rows
    mismatches = [r["name"] for r in rows
                  if "match" in r and not r["match"]
                  or "match_paper" in r and not r["match_paper"]]
    assert not mismatches, mismatches


def test_table3_predictions_close_to_measurements():
    rows = paper_tables.table3()
    # O1/O2 rows: best-case bound within 5% of the paper's measurements
    close = [r for r in rows if r["name"].endswith(("O1", "O2"))]
    assert close
    for r in close:
        assert r["rel_err"] < 0.05, r


def test_table5_combined_bound_improves_on_port_bound():
    """Beyond-paper: max(TP bound, LCD) explains the -O1 outliers the
    paper could only measure (Sec. III-B)."""
    rows = {r["name"]: r for r in paper_tables.table5()}
    for arch in ("skl", "zen"):
        r = rows[f"table5/pi_{arch}_O1"]
        port_err = abs(r["pred_tp_cy_it"] - r["paper_measured_cy_it"]) \
            / r["paper_measured_cy_it"]
        assert r["combined_rel_err"] < 0.05 < port_err


def test_simulator_table_covers_both_archs_and_converges():
    """The third-backend comparison column (ISSUE 2): every paper
    kernel on both CPU models, converged, within 15% of the analytic
    prediction for the dependency-free triad and the LCD-bound pi -O1."""
    rows = {r["name"]: r for r in paper_tables.simulator_table()}
    assert any("skl" in n for n in rows) and any("zen" in n for n in rows)
    for r in rows.values():
        assert r["converged"], r
        assert r["sim_cy_it"] > 0
    for name in ("simulator/triad_zen_O3", "simulator/pi_skl_O1"):
        assert abs(rows[name]["rel_to_analytic"]) <= 0.15, rows[name]


def test_roofline_constants_single_sourced():
    """Regression for the constants overlap: ``benchmarks/roofline.py``
    must price with the registry's machine-model artifact — the same
    numbers the HLO analyzer and the ``tpu_v5e`` module export — so the
    report cannot drift from the prediction path."""
    from benchmarks import roofline
    from repro.core.arch import tpu_v5e
    from repro.core.arch.registry import get_model

    constants = get_model("tpu_v5e").constants
    assert roofline.PEAK == constants["peak_flops"]["bf16"] \
        == tpu_v5e.PEAK_FLOPS["bf16"]
    assert roofline.HBM_BW == constants["hbm_bw"] == tpu_v5e.HBM_BW
    # the working-set level table ships with the model too (docs/ecm.md)
    assert constants["mem_levels"] == tpu_v5e.MEM_LEVELS
    assert constants["mem_levels"][-1]["size"] is None


@pytest.mark.skipif(
    not os.path.exists("results/dryrun_baseline.json"),
    reason="dry-run artifacts not present")
def test_roofline_report_parses_dryrun():
    from benchmarks.roofline import report
    rows = report("results/dryrun_baseline.json", mesh="16x16")
    ok = [r for r in rows if "skipped" not in r]
    skipped = [r for r in rows if "skipped" in r]
    assert len(ok) + len(skipped) == 40
    assert len(skipped) == 8
    for r in ok:
        assert r["compute_s"] > 0 and r["bound_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert 0 < r["roofline_fraction"] < 1
