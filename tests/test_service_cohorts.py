"""Property tests for the service's cohort/batch former.

Satellite guarantees of the prediction service (``repro.service``):

* ``form_cohorts`` is a *partition* of the in-flight request list —
  every request lands in exactly one cohort, no index is dropped or
  duplicated, regardless of the traffic mix (hypothesis);
* a cohort never mixes incompatible requests: all members share one
  ``cohort_key`` — same kind (x86 vs HLO), same resolved machine
  digest, same mode, same backend (and same pricing knobs for HLO);
* ``max_cohort`` splits oversized cohorts without breaking either
  property;
* batching is *semantically invisible*: results produced through the
  batched dispatch path are bit-identical to per-request
  ``AnalysisService.predict`` on a fresh engine — for the analytic
  path under hypothesis-generated mixes, and for the full
  queue → cohort → ``simulate_many`` service path on the matched
  kernel x arch grid (the pairs pinned identical across simulator
  drivers by tests/test_sweep_engine.py).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # optional [dev] dependency
    from repro.testing import given, settings, st

from repro.core import AnalysisRequest, AnalysisService, default_service
from repro.core import paper_kernels as pk
from repro.service import (HloRequest, PredictionService, ServiceConfig,
                           ServiceRequest, cohort_key, form_cohorts,
                           is_partition, replay)

SERVICE = default_service()

HLO_A = """
HloModule a, entry_computation_layout={()->f32[64,64]{1,0}}

ENTRY %main.1 () -> f32[64,64] {
  %a = f32[64,64]{1,0} constant({...})
  ROOT %d = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
HLO_B = """
HloModule b, entry_computation_layout={()->f32[128,128]{1,0}}

ENTRY %main.1 () -> f32[128,128] {
  %a = f32[128,128]{1,0} constant({...})
  %x = f32[128,128]{1,0} add(%a, %a)
  ROOT %d = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

# matched kernel x arch pairs: every driver (tick loop, numpy batch,
# jit batch) is pinned bit-identical on these by the sweep-engine suite
MATCHED = [("skl", pk.TRIAD_SKL_O3), ("zen", pk.TRIAD_ZEN_O3),
           ("skl", pk.PI_O1), ("zen", pk.PI_O1),
           ("skl", pk.PI_O2), ("zen", pk.PI_O2),
           ("skl", pk.PI_SKL_O3), ("zen", pk.PI_ZEN_O3)]


def _request_pool() -> list[ServiceRequest]:
    pool = []
    for arch, src in MATCHED:
        for mode in ("analytic", "simulate"):
            for sched in ("uniform", "balanced"):
                for backend in (None, "numpy"):
                    pool.append(ServiceRequest(
                        analysis=AnalysisRequest(
                            kernel=src, arch=arch, scheduler=sched,
                            mode=mode),
                        backend=backend, tenant="t%d" % (len(pool) % 3)))
    for text in (HLO_A, HLO_B):
        for ici in (1.0, 2.0):
            for dtype in ("bf16", "f32"):
                pool.append(ServiceRequest(
                    hlo=HloRequest(text=text, ici_links=ici,
                                   flop_dtype=dtype),
                    tenant="hlo"))
    return pool


POOL = _request_pool()


def _signature(sreq: ServiceRequest, result) -> tuple:
    if sreq.analysis is not None:
        return (result.predicted_cycles, result.port_bound_cycles,
                result.lcd_cycles, result.bound_sim, result.binding)
    t = result.terms
    return (t.bound_combined, t.bound_overlap, t.critical_path_s)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=len(POOL) - 1),
                min_size=0, max_size=40),
       st.one_of(st.none(), st.integers(min_value=1, max_value=5)))
def test_cohorts_partition_and_never_mix(idxs, max_cohort):
    """form_cohorts partitions any traffic mix; members always agree
    on the full cohort key; max_cohort caps cohort size."""
    requests = [POOL[i] for i in idxs]
    cohorts = form_cohorts(SERVICE, requests, max_cohort=max_cohort)

    assert is_partition(cohorts, len(requests))
    seen = sorted(i for _, members in cohorts for i in members)
    assert seen == list(range(len(requests)))

    for key, members in cohorts:
        assert members, "empty cohort emitted"
        if max_cohort is not None:
            assert len(members) <= max_cohort
        for i in members:
            assert cohort_key(SERVICE, requests[i]) == key

    # no two cohorts share a key unless forced apart by max_cohort
    if max_cohort is None:
        keys = [k for k, _ in cohorts]
        assert len(keys) == len(set(keys))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=len(POOL) - 1),
                min_size=1, max_size=40))
def test_cohorts_never_mix_incompatible(idxs):
    """Explicit incompatibility axes: kind, machine digest, mode,
    backend (and HLO pricing knobs) are constant within a cohort."""
    requests = [POOL[i] for i in idxs]
    for _, members in form_cohorts(SERVICE, requests):
        group = [requests[i] for i in members]
        kinds = {r.kind for r in group}
        assert len(kinds) == 1
        if kinds == {"x86"}:
            digests = {SERVICE.resolve_machine(r.analysis.arch).digest
                       for r in group}
            modes = {r.analysis.mode for r in group}
        else:
            digests = {SERVICE.resolve_machine(r.hlo.machine).digest
                       for r in group}
            modes = {r.hlo.mode for r in group}
            assert len({(r.hlo.ici_links, r.hlo.flop_dtype,
                         r.hlo.working_set) for r in group}) == 1
        assert len(digests) == 1
        assert len(modes) == 1
        assert len({r.backend for r in group}) == 1


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(
    st.integers(min_value=0, max_value=len(MATCHED) - 1),
    st.sampled_from(["uniform", "balanced"])),
    min_size=1, max_size=8))
def test_analytic_batch_identical_to_per_request(cells):
    """predict_batch == predict, field for field, on fresh engines."""
    reqs = [AnalysisRequest(kernel=MATCHED[i][1], arch=MATCHED[i][0],
                            scheduler=sched) for i, sched in cells]
    batched = AnalysisService().predict_batch(reqs)
    serial_engine = AnalysisService()
    for req, got in zip(reqs, batched):
        want = serial_engine.predict(req)
        assert _signature(ServiceRequest(analysis=req), got) == \
            _signature(ServiceRequest(analysis=req), want)


def test_service_batched_results_bit_identical():
    """The full queue -> cohort -> simulate_many service path returns
    bit-identical results to per-request predict on a fresh engine,
    for a mixed simulate/analytic/HLO traffic burst."""
    traffic = []
    for i, (arch, src) in enumerate(MATCHED[:4]):
        traffic.append((0.0, ServiceRequest(
            analysis=AnalysisRequest(kernel=src, arch=arch,
                                     mode="simulate"),
            tenant="a" if i % 2 else "b")))
    traffic.append((0.0, ServiceRequest(
        analysis=AnalysisRequest(kernel=pk.PI_O1, arch="skl"),
        tenant="a")))
    traffic.append((0.0, ServiceRequest(hlo=HloRequest(text=HLO_A),
                                        tenant="b")))

    svc = PredictionService(config=ServiceConfig(
        batch_window_s=0.01, backend="numpy"))
    resps = replay(svc, traffic)
    assert all(r.ok for r in resps), [r.error for r in resps]
    # batching actually happened: the 4 simulate cells form 2 cohorts
    # (one per machine model), not 4 singleton dispatches
    sim_sizes = [r.cohort_size for r in resps[:4]]
    assert max(sim_sizes) >= 2

    engine = AnalysisService()
    for (_, sreq), resp in zip(traffic, resps):
        if sreq.analysis is not None:
            want = engine.predict(sreq.analysis)
        else:
            want = engine.predict_hlo(sreq.hlo.text)
        assert _signature(sreq, resp.result) == _signature(sreq, want)


def test_cohort_key_distinguishes_machines_and_modes():
    r_skl = ServiceRequest(analysis=AnalysisRequest(kernel=pk.PI_O1,
                                                    arch="skl"))
    r_zen = ServiceRequest(analysis=AnalysisRequest(kernel=pk.PI_O1,
                                                    arch="zen"))
    r_sim = ServiceRequest(analysis=AnalysisRequest(
        kernel=pk.PI_O1, arch="skl", mode="simulate"))
    r_hlo = ServiceRequest(hlo=HloRequest(text=HLO_A))
    keys = {cohort_key(SERVICE, r) for r in
            (r_skl, r_zen, r_sim, r_hlo)}
    assert len(keys) == 4

    # same machine resolved under an alias must share a cohort
    assert cohort_key(SERVICE, r_skl) == cohort_key(
        SERVICE, ServiceRequest(analysis=AnalysisRequest(
            kernel=pk.PI_O1, arch="skylake")))


def test_oversized_cohort_split_is_stable():
    requests = [ServiceRequest(analysis=AnalysisRequest(
        kernel=pk.PI_O1, arch="skl", unroll_factor=1 + i))
        for i in range(7)]
    cohorts = form_cohorts(SERVICE, requests, max_cohort=3)
    assert [len(m) for _, m in cohorts] == [3, 3, 1]
    assert is_partition(cohorts, len(requests))
    flat = [i for _, m in cohorts for i in m]
    assert flat == list(range(7))
