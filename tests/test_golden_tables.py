"""Golden-table regression suite for the simulator comparison table.

``benchmarks.paper_tables.simulator_table`` runs every paper kernel
through the cycle-level pipeline simulator (front-end model enabled via
the shipped SKL/Zen machine models) and next to the analytic
``max(port bound, LCD)`` prediction.  This module pins the whole table
against committed golden values: any change to the simulator, the
front-end schedule, or the machine models that moves a paper-kernel
number shows up here as an explicit diff, not as silent drift.

On mismatch the failing rows are also written to a machine-readable
diff file (``GOLDEN_DIFF_PATH``, default ``golden-table-diff.json`` in
the repo root) which CI uploads as an artifact.
"""
import json
import os
from pathlib import Path

import pytest

from benchmarks import paper_tables
from repro.core import paper_kernels as pk

# ------------------------------------------------------------------ #
# The golden table.  Values are per *source* iteration; ``sim`` comes
# from the cycle-level simulator with the front-end model enabled
# (uiCA-style predecode/decode/DSB/LSD + macro/micro fusion), ``analytic``
# is max(port bound, LCD).  Regenerate with
#   PYTHONPATH=src:. python -c \
#     "from benchmarks.paper_tables import simulator_table; \
#      [print(r) for r in simulator_table()]"
# and update ONLY when a change to the model is intended and understood.
# ------------------------------------------------------------------ #
GOLDEN = {
    #                 analytic  sim     binding       sim_bottleneck
    "triad_skl_O3": (0.50, 0.50, "throughput", "frontend"),
    "triad_zen_O3": (1.00, 1.00, "throughput", "ports"),
    "pi_skl_O1":    (9.00, 9.00, "latency",    "dependencies"),
    "pi_skl_O2":    (4.25, 4.00, "simulation", "ports"),
    "pi_skl_O3":    (2.00, 2.00, "throughput", "ports"),
    "pi_zen_O1":    (11.50, 12.00, "simulation", "dependencies"),
    "pi_zen_O2":    (4.00, 4.00, "throughput", "ports"),
    "pi_zen_O3":    (2.00, 2.00, "throughput", "ports"),
}

ABS_TOL = 1e-9


def _diff_path() -> Path:
    root = Path(__file__).resolve().parent.parent
    return Path(os.environ.get("GOLDEN_DIFF_PATH",
                               root / "golden-table-diff.json"))


@pytest.fixture(scope="module")
def sim_rows():
    rows = {r["name"].split("/", 1)[1]: r
            for r in paper_tables.simulator_table()}
    yield rows


def _check_rows(rows):
    """Compare against GOLDEN; return the list of mismatch records."""
    diffs = []
    for name, (analytic, sim, binding, bottleneck) in GOLDEN.items():
        row = rows.get(name)
        if row is None:
            diffs.append({"kernel": name, "field": "row",
                          "expected": "present", "got": "missing"})
            continue
        checks = [
            ("analytic_cy_it", analytic, row["analytic_cy_it"]),
            ("sim_cy_it", sim, row["sim_cy_it"]),
            ("binding", binding, row["binding"]),
            ("sim_bottleneck", bottleneck, row["sim_bottleneck"]),
            ("converged", True, row["converged"]),
        ]
        for field, exp, got in checks:
            equal = (abs(got - exp) <= ABS_TOL
                     if isinstance(exp, float) else got == exp)
            if not equal:
                diffs.append({"kernel": name, "field": field,
                              "expected": exp, "got": got})
    return diffs


def test_simulator_table_matches_golden(sim_rows):
    assert set(sim_rows) == set(GOLDEN), (
        "kernel set drifted vs golden table")
    diffs = _check_rows(sim_rows)
    if diffs:
        path = _diff_path()
        path.write_text(json.dumps(
            {"golden": {k: list(v) for k, v in GOLDEN.items()},
             "diffs": diffs}, indent=2) + "\n", encoding="utf-8")
        pytest.fail(f"{len(diffs)} golden-table mismatch(es), diff "
                    f"written to {path}:\n"
                    + "\n".join(f"  {d['kernel']}.{d['field']}: expected "
                                f"{d['expected']!r}, got {d['got']!r}"
                                for d in diffs))


def test_triad_skl_sim_within_10pct_of_measurement(sim_rows):
    """The front-end model is what closes the triad gap: the slot-domain
    issue bound (9 uops -> 7 fused slots / 4-wide) predicts 0.50 cy per
    source iteration vs the paper's measured 0.53 (Table III) — within
    10%, where the unfused uop count alone sat ~+25% off at 0.5625+.
    """
    measured = pk.TABLE3_MEASURED[("skl", "skl", "O3")]
    sim = sim_rows["triad_skl_O3"]["sim_cy_it"]
    rel = abs(sim - measured) / measured
    assert rel < 0.10, (sim, measured, rel)


def test_frontend_binds_the_skl_triad(sim_rows):
    """On SKL the fused-domain issue width is the binding stage for the
    -O3 triad; everywhere else ports or the dependency chain bind."""
    assert sim_rows["triad_skl_O3"]["sim_bottleneck"] == "frontend"
    others = [n for n in GOLDEN if n != "triad_skl_O3"]
    assert all(sim_rows[n]["sim_bottleneck"] in ("ports", "dependencies")
               for n in others)


def test_no_stale_diff_artifact_on_success(sim_rows):
    """A green run must not leave a stale diff file behind (CI only
    uploads it on failure, but a leftover from a previous red run would
    be misleading)."""
    if not _check_rows(sim_rows) and _diff_path().exists():
        _diff_path().unlink()
    assert not (_check_rows(sim_rows) and not _diff_path().exists())
