"""MachineModel spec + ArchRegistry (ISSUE 3).

Locks the declarative machine-model artifact: JSON round-trip identity
for all shipped architectures, ``derive()`` override semantics, registry
alias resolution / duplicate-registration errors / database caching,
``from_benchmarks`` inference against the hand-written tables, and —
the acceptance criterion — ``AnalysisService`` parity: a registry-loaded
JSON model produces identical ``AnalysisResult``s (analytic *and*
simulate modes) to the hardcoded builders on all paper kernels.
"""
import json
from pathlib import Path

import pytest

from repro.core import (AnalysisRequest, AnalysisService, BenchRecord,
                        MachineModel, UnknownArchError, analyze,
                        as_database, extract_kernel, get_model)
from repro.core import paper_kernels as pk
from repro.core.arch import canonical_arch, get_db
from repro.core.arch.registry import (ArchRegistry, MODELS_DIR,
                                      default_registry)
from repro.core.database import InstructionDB
from repro.core.machine import SCHEMA

ARCHS = ("skl", "zen", "tpu_v5e")

PAPER_KERNELS = {
    "triad_skl_O3": ("skl", pk.TRIAD_SKL_O3, 4),
    "triad_zen_O3": ("zen", pk.TRIAD_ZEN_O3, 2),
    "pi_skl_O1": ("skl", pk.PI_O1, 1),
    "pi_skl_O2": ("skl", pk.PI_O2, 1),
    "pi_skl_O3": ("skl", pk.PI_SKL_O3, 8),
    "pi_zen_O1": ("zen", pk.PI_O1, 1),
    "pi_zen_O2": ("zen", pk.PI_O2, 1),
    "pi_zen_O3": ("zen", pk.PI_ZEN_O3, 2),
}


# ---------------------------------------------------------------------------
# serialization round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_json_round_trip_is_identity(arch):
    model = get_model(arch)
    assert MachineModel.from_dict(model.to_dict()) == model
    assert MachineModel.from_json(model.to_json()) == model
    # digest is a stable content address of the canonical JSON
    assert MachineModel.from_json(model.to_json()).digest == model.digest


def test_digest_is_stable_across_processes():
    """The digest is a content address: it must not depend on hash
    randomization (set iteration order during form-table construction
    once leaked into it)."""
    import os
    import subprocess
    import sys
    code = ("from repro.core import get_model; "
            "print(get_model('skl').digest, get_model('zen').digest)")
    outs = set()
    for seed in ("1", "2"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (str(Path(__file__).parent.parent / "src"),
                        env.get("PYTHONPATH")) if p)
        outs.add(subprocess.check_output(
            [sys.executable, "-c", code], env=env, text=True).strip())
    assert len(outs) == 1, outs


def test_to_dict_is_json_serializable_and_schema_tagged():
    d = get_model("skl").to_dict()
    assert d["schema"] == SCHEMA
    json.dumps(d)  # no exotic types anywhere in the tree
    assert d["aliases"] == ["skylake"]
    assert d["pipeline"]["issue_width"] == 4


def test_from_dict_rejects_unknown_schema():
    d = get_model("skl").to_dict()
    d["schema"] = "repro.machine-model/v999"
    with pytest.raises(ValueError, match="schema"):
        MachineModel.from_dict(d)


def test_spec_validation():
    with pytest.raises(ValueError, match="duplicate ports"):
        MachineModel(arch_id="x", name="x", ports=("0", "0"))
    with pytest.raises(ValueError, match="divider"):
        MachineModel(arch_id="x", name="x", ports=("0",),
                     divider_ports=("1",))
    with pytest.raises(ValueError, match="lowercase"):
        MachineModel(arch_id="X", name="x", ports=("0",))
    with pytest.raises(ValueError, match="unknown ports"):
        MachineModel.from_dict({
            "arch_id": "x", "name": "x", "ports": ["0"],
            "forms": [{"mnemonic": "f", "signature": ["r"],
                       "uops": [{"ports": ["9"]}],
                       "throughput": 1, "latency": 1}]})


# ---------------------------------------------------------------------------
# derive()
# ---------------------------------------------------------------------------

def test_derive_overrides_and_resets_aliases():
    skl = get_model("skl")
    d = skl.derive("skl2", frequency_hz=2.4e9)
    assert d.arch_id == "skl2"
    assert d.aliases == ()            # derived models don't steal names
    assert d.frequency_hz == 2.4e9
    assert d.name == skl.name         # everything else inherited
    assert d.forms is skl.forms       # the big table is shared, not copied
    assert d.pipeline == skl.pipeline
    # the base model is untouched
    assert skl.frequency_hz == 1.8e9 and skl.aliases == ("skylake",)


def test_derive_rejects_unknown_fields():
    with pytest.raises(TypeError, match="unknown MachineModel fields"):
        get_model("skl").derive("x", issue_width=8)


def test_shipped_derived_models_resolve_and_predict():
    reg = default_registry()
    assert reg.resolve("cascadelake") == "clx"
    assert reg.resolve("zen+") == "zenplus"
    clx = get_model("clx")
    assert clx.forms == get_model("skl").forms
    res = analyze(list(extract_kernel(pk.PI_O1)), "clx")
    ref = analyze(list(extract_kernel(pk.PI_O1)), "skl")
    assert res.predicted_cycles == ref.predicted_cycles == 9.0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_alias_resolution_and_case_insensitivity():
    assert canonical_arch("SKYLAKE") == "skl"
    assert canonical_arch("znver1") == "zen"
    assert canonical_arch("TPU") == "tpu_v5e"


def test_unknown_arch_raises_one_consistent_error():
    with pytest.raises(UnknownArchError) as ei:
        canonical_arch("sparc")
    msg = str(ei.value)
    assert "sparc" in msg and "skl" in msg and "'skylake'->'skl'" in msg
    # get_db now raises the same error (the old one silently passed
    # unknown names through canonical_arch and raised a stale message)
    with pytest.raises(UnknownArchError):
        get_db("sparc")
    # subclasses both historical exception types
    assert issubclass(UnknownArchError, ValueError)
    assert issubclass(UnknownArchError, KeyError)


def test_registry_caches_databases():
    db1 = get_db("skl")
    db2 = get_db("skylake")
    assert db1 is db2                 # built once, alias-stable
    assert isinstance(db1, InstructionDB)


def test_duplicate_registration_errors():
    reg = ArchRegistry()
    m = MachineModel(arch_id="a", name="A", ports=("0",),
                     aliases=("aa",))
    reg.register(m)
    with pytest.raises(ValueError, match="already registered"):
        reg.register(m)
    # alias clash with an existing id/alias also errors
    clash = MachineModel(arch_id="b", name="B", ports=("0",),
                         aliases=("aa",))
    with pytest.raises(ValueError, match="already registered"):
        reg.register(clash)
    # replace=True shadows
    reg.register(MachineModel(arch_id="a", name="A2", ports=("0",)),
                 replace=True)
    assert reg.model("a").name == "A2"


def test_child_registry_shadows_without_leaking():
    child = ArchRegistry(parent=default_registry())
    assert child.resolve("skylake") == "skl"     # parent fallthrough
    toy = MachineModel(arch_id="skl", name="shadow", ports=("0",))
    child.register(toy, replace=True)
    assert child.model("skl").name == "shadow"
    assert default_registry().model("skl").name == "Intel Skylake"


def test_service_registration_is_service_local():
    svc = AnalysisService()
    svc.register(get_model("skl").derive("mine"))
    assert svc.predict(AnalysisRequest(kernel=pk.PI_O2, arch="mine"))
    other = AnalysisService()
    with pytest.raises(UnknownArchError):
        other.predict(AnalysisRequest(kernel=pk.PI_O2, arch="mine"))


def test_load_file_full_and_derived(tmp_path):
    reg = ArchRegistry(parent=default_registry())
    full = tmp_path / "full.json"
    full.write_text(get_model("zen").derive("zcopy").to_json())
    assert reg.load_file(full) == "zcopy"
    assert reg.model("zcopy").name == "AMD Zen"
    derived = tmp_path / "derived.json"
    derived.write_text(json.dumps({
        "schema": SCHEMA, "base": "skl",
        "overrides": {"arch_id": "lab", "aliases": ["labskl"],
                      "frequency_hz": 3.0e9}}))
    assert reg.load_file(derived) == "lab"
    assert reg.resolve("labskl") == "lab"
    assert reg.model("lab").frequency_hz == 3.0e9
    assert reg.database("lab").lookup is not None


def test_models_dir_is_discovered():
    assert MODELS_DIR.is_dir()
    shipped = {p.stem for p in MODELS_DIR.glob("*.json")}
    assert {"cascadelake", "zenplus", "toy"} <= shipped
    for arch in ("clx", "zenplus", "toy2"):
        assert arch in default_registry().ids()


# ---------------------------------------------------------------------------
# acceptance: registry-loaded JSON model == hardcoded builders
# ---------------------------------------------------------------------------

def _results_equal(a, b):
    assert a.predicted_cycles == b.predicted_cycles
    assert a.port_bound_cycles == b.port_bound_cycles
    assert a.lcd_cycles == b.lcd_cycles
    assert a.port_totals == b.port_totals
    assert a.binding == b.binding
    assert a.bound_sim == b.bound_sim
    assert [r.occupation for r in a.rows] == [r.occupation for r in b.rows]


@pytest.mark.parametrize("mode", ["analytic", "simulate"])
def test_registry_loaded_json_model_matches_hardcoded(tmp_path, mode):
    """A model written to JSON, loaded back through a registry and
    registered on a fresh service reproduces the hardcoded builders'
    AnalysisResults on every paper kernel — analytic and simulate."""
    svc = AnalysisService()
    loaded_ids = {}
    for arch in ("skl", "zen"):
        path = tmp_path / f"{arch}.json"
        path.write_text(get_model(arch).derive(f"{arch}j").to_json())
        loaded_ids[arch] = svc.registry.load_file(path)
    ref_svc = AnalysisService()
    for name, (arch, src, unroll) in PAPER_KERNELS.items():
        ref = ref_svc.predict(AnalysisRequest(
            kernel=src, arch=arch, unroll_factor=unroll, mode=mode))
        got = svc.predict(AnalysisRequest(
            kernel=src, arch=loaded_ids[arch], unroll_factor=unroll,
            mode=mode))
        _results_equal(got, ref)


# ---------------------------------------------------------------------------
# register_db migration shim
# ---------------------------------------------------------------------------

def test_register_db_shim_warns_and_matches_register():
    from repro.core.arch.skylake import build_skylake_db
    svc = AnalysisService()
    with pytest.warns(DeprecationWarning, match="register_db"):
        svc.register_db("legacy", build_skylake_db())
    old = svc.predict(AnalysisRequest(kernel=pk.PI_O2, arch="legacy"))
    svc2 = AnalysisService()
    svc2.register(MachineModel.from_db("modern", build_skylake_db()))
    new = svc2.predict(AnalysisRequest(kernel=pk.PI_O2, arch="modern"))
    _results_equal(old, new)


# ---------------------------------------------------------------------------
# from_benchmarks (semi-automatic construction, paper Sec. II-B)
# ---------------------------------------------------------------------------

def _records(form, latency, rtp, signature="v,v,v"):
    """Synthesize an ibench sweep for a form with the given lat/rTP."""
    recs = [BenchRecord(form=form, parallelism=1, value=latency,
                        signature=signature)]
    for p in (2, 4, 8, 10):
        # per-op time saturates at the reciprocal throughput
        recs.append(BenchRecord(form=form, parallelism=p,
                                value=max(rtp, latency / p),
                                signature=signature))
    return recs


def test_from_benchmarks_matches_skylake_table():
    """Port counts inferred from synthetic measurements of the paper's
    own lat/TP numbers match the hand-written Skylake/Zen entries."""
    skl = get_model("skl")
    cases = {
        # mnemonic: latency, rTP, expected port count of the main uop
        "vaddpd": (4.0, 0.5, 2),      # FP pipes 0|1
        "vfmadd132pd": (4.0, 0.5, 2),
        "add": (1.0, 0.25, 4),        # scalar ALU 0|1|5|6
        "vdivpd": (14.0, 8.0, 1),     # divider: unpipelined single port
    }
    records = []
    for form, (lat, rtp, _) in cases.items():
        records += _records(form, lat, rtp)
    m = MachineModel.from_benchmarks(records, arch_id="meas",
                                     name="measured")
    assert m.ports == ("p0", "p1", "p2", "p3")
    by_name = {f.mnemonic: f for f in m.forms}
    for form, (lat, rtp, n_ports) in cases.items():
        f = by_name[form]
        assert len(f.uops[0].ports) == n_ports, form
        assert f.latency == lat and f.throughput == rtp
        # occupation reproduces the measured reciprocal throughput
        occ = f.occupation_uniform(m.port_model)
        assert max(occ.values()) == pytest.approx(rtp)
    # sanity against the real tables: same port-set sizes as hand-written
    from repro.core import parse_assembly
    vadd = as_database(skl).lookup(
        parse_assembly("vaddpd %ymm0, %ymm1, %ymm2")[0])
    assert len(vadd.uops[0].ports) == \
        len(by_name["vaddpd"].uops[0].ports)


def test_from_benchmarks_requires_latency_record():
    with pytest.raises(ValueError, match="latency"):
        MachineModel.from_benchmarks(
            [BenchRecord(form="f", parallelism=2, value=0.5)],
            arch_id="x")


def test_from_benchmarks_round_trips():
    m = MachineModel.from_benchmarks(_records("fma", 4.0, 0.5),
                                     arch_id="meas")
    assert MachineModel.from_json(m.to_json()) == m


# ---------------------------------------------------------------------------
# pipeline coercion: one model object parameterizes everything
# ---------------------------------------------------------------------------

def test_as_database_coercions():
    db = as_database("skl")
    assert as_database(db) is db                      # pass-through
    assert as_database(get_model("skl")) is db        # model -> cached db
    with pytest.raises(TypeError):
        as_database(42)


def test_formless_models_are_rejected_on_the_instruction_path():
    """The TPU model has no form table: instruction-stream analysis on
    it must error (as the pre-registry get_db did), not silently match
    nothing."""
    with pytest.raises(ValueError, match="no instruction-form table"):
        get_db("tpu")
    with pytest.raises(ValueError, match="no instruction-form table"):
        as_database(get_model("tpu_v5e"))
    with pytest.raises(ValueError, match="no instruction-form table"):
        AnalysisService().predict(
            AnalysisRequest(kernel=pk.PI_O1, arch="tpu"))


def test_register_under_alias_spelling_shadows_canonical():
    """register(model with arch_id='skylake') must shadow 'skl' (the
    register_db semantics), not split the alias from its canonical id."""
    zen_as_skylake = get_model("zen").derive("skylake")
    svc = AnalysisService()
    assert svc.register(zen_as_skylake) == "skl"
    for spelling in ("skl", "skylake"):
        r = svc.predict(AnalysisRequest(kernel=pk.PI_O1, arch=spelling))
        assert r.model.name == "AMD Zen", spelling


def test_constants_normalize_for_round_trip():
    tpu = get_model("tpu_v5e")
    m = tpu.derive("custom", constants={**tpu.constants, "mesh": (4, 2)})
    assert m.constants["mesh"] == [4, 2]      # canonical JSON form
    assert MachineModel.from_dict(m.to_dict()) == m


def test_hlo_machine_constants_merge_and_vpu_weights():
    """A derived accelerator overriding one constant must not KeyError
    on the others, and vpu_op_weight overrides must take effect."""
    from repro.core.hlo.analyzer import analyze_hlo
    hlo = """
HloModule m

ENTRY %main (p0: f32[1024,1024]) -> f32[1024,1024] {
  %p0 = f32[1024,1024] parameter(0)
  ROOT %exp = f32[1024,1024] exponential(%p0)
}
"""
    tpu = get_model("tpu_v5e")
    base = analyze_hlo(hlo, machine=tpu)
    partial = tpu.derive("fast_hbm",
                         constants={"hbm_bw": tpu.constants["hbm_bw"] * 2})
    fast = analyze_hlo(hlo, machine=partial)          # no KeyError
    assert fast.terms.memory_s == pytest.approx(base.terms.memory_s / 2)
    assert fast.terms.vpu_s == base.terms.vpu_s
    heavy = tpu.derive("heavy_vpu", constants={
        "vpu_op_weight": {"exponential": 8.0}})
    assert analyze_hlo(hlo, machine=heavy).terms.vpu_s == \
        pytest.approx(2 * base.terms.vpu_s)           # weight 4 -> 8


def test_analyze_and_simulate_accept_models_and_ids():
    from repro.core import compile_program, simulate
    kern = list(extract_kernel(pk.PI_O1))
    by_id = analyze(kern, "skl")
    by_model = analyze(kern, get_model("skl"))
    _results_equal(by_id, by_model)
    sim = simulate(compile_program(kern, "skl"))
    assert sim.converged and sim.cycles_per_iteration == \
        pytest.approx(9.0, abs=0.01)


# ---------------------------------------------------------------------------
# Front-end parameters (uiCA-style fetch/decode model) as model fields
# ---------------------------------------------------------------------------
def _load_check_models():
    """Import tools/check_models.py as a module (it is a script, not a
    package — CI runs it directly)."""
    import importlib.util
    path = Path(__file__).resolve().parent.parent / "tools" / \
        "check_models.py"
    spec = importlib.util.spec_from_file_location("check_models", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_frontend_fields_serialize_and_round_trip():
    """The front-end block is part of the artifact: it appears in
    to_dict(), survives the JSON round trip, and carries the shipped
    SKL/Zen values."""
    skl = get_model("skl")
    pl = skl.to_dict()["pipeline"]
    assert pl["predecode_width"] == 5 and pl["decode_width"] == 4
    assert pl["complex_decode_width"] == 1
    assert pl["dsb_width"] == 6 and pl["dsb_size"] == 1536
    assert pl["lsd_size"] == 64
    assert pl["macro_fusion"] and pl["micro_fusion"] \
        and pl["move_elimination"]
    assert pl["mispredict_penalty"] == 17.0
    zen = get_model("zen").to_dict()["pipeline"]
    # Zen: four symmetric complex-capable decoders, op cache, no LSD
    assert zen["complex_decode_width"] == 4
    assert zen["dsb_width"] == 8 and zen["lsd_size"] == 0
    for arch in ("skl", "zen"):
        m = get_model(arch)
        clone = MachineModel.from_json(m.to_json())
        assert clone == m and clone.pipeline == m.pipeline


def test_pre_frontend_artifact_loads_with_stages_disabled():
    """A model file written before the front-end block existed (only
    the four window fields) still loads — with every front-end stage
    disabled, i.e. the pre-front-end simulator semantics."""
    d = get_model("skl").to_dict()
    d["pipeline"] = {k: d["pipeline"][k]
                    for k in ("issue_width", "rob_size",
                              "scheduler_size", "retire_width")}
    old = MachineModel.from_dict(d)
    p = old.pipeline
    assert p.predecode_width == 0 and p.decode_width == 0
    assert p.dsb_width == 0 and p.dsb_size == 0 and p.lsd_size == 0
    assert not (p.macro_fusion or p.micro_fusion or p.move_elimination)
    assert p.mispredict_penalty == 0.0
    assert p.complex_decode_width == 1


def test_derive_overrides_frontend_params():
    import dataclasses
    base = get_model("skl")
    narrow = dataclasses.replace(base.pipeline, dsb_width=0, dsb_size=0,
                                 lsd_size=0)
    variant = base.derive("skl-mite-only", pipeline=narrow)
    assert variant.pipeline.dsb_width == 0
    assert variant.pipeline.predecode_width == 5   # untouched fields kept
    assert base.pipeline.dsb_width == 6            # base unchanged


def test_digest_tracks_frontend_fields():
    import dataclasses
    base = get_model("skl")
    tweaked = base.derive("skl-fe-probe", pipeline=dataclasses.replace(
        base.pipeline, macro_fusion=False))
    same = base.derive("skl-fe-probe", pipeline=base.pipeline)
    # an explicit (but value-identical) pipeline leaves the digest alone;
    # flipping a single front-end flag moves it
    assert same.digest == base.derive("skl-fe-probe").digest
    assert tweaked.digest != same.digest


def test_check_models_rejects_inconsistent_frontend_widths():
    import dataclasses
    cm = _load_check_models()
    base = get_model("skl")

    def errors_for(**kw):
        bad = base.derive("skl-bad", pipeline=dataclasses.replace(
            base.pipeline, **kw))
        errs = []
        cm.check_model(bad, "test-artifact", errs)
        return errs

    assert not errors_for()                       # shipped values pass
    assert any("decode_width" in e
               for e in errors_for(decode_width=8))
    assert any("predecode_width" in e
               for e in errors_for(predecode_width=2))
    assert any("complex_decode_width" in e
               for e in errors_for(complex_decode_width=9,
                                   decode_width=4))
    assert any("dsb_width" in e for e in errors_for(dsb_size=0))


def test_check_models_main_passes_on_shipped_artifacts():
    cm = _load_check_models()
    assert cm.main() == 0
